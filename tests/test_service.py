"""Streaming dedup service: scheduler exactness, round trip, GC, estimator.

Acceptance-criteria coverage (docs/SERVICE.md): the batched scheduler is
bit-identical to per-stream ``boundaries_two_phase``; ingest+restore of a
version corpus is SHA-verified byte-identical with dedup ratio > 1.5x; the
store survives deletes, GC, and restarts with consistent accounting.
"""
import json
import os
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import seqcdc
from repro.core.params import SeqCDCParams
from repro.data.corpus import snapshot_series
from repro.dedup import BlockStore
from repro.dedup.fingerprint import fingerprints_numpy
from repro.service import ChunkScheduler, DedupService, IntegrityError

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)


def _exact(data: np.ndarray) -> list:
    if data.size == 0:
        return []
    b, c = seqcdc.boundaries_two_phase(jnp.asarray(data), P)
    return seqcdc.bounds_to_numpy(b, c)


# -- scheduler ------------------------------------------------------------------

def test_scheduler_bit_identical_mixed_lengths(rng):
    """Mixed traffic (edge lengths incl. empty, < seq_length, == max_size,
    == bucket size) chunks bit-identically to the per-stream pipeline."""
    sched = ChunkScheduler(P, slots=4, min_bucket=1024)
    lengths = [0, 1, 2, P.seq_length - 1, 100, P.max_size, P.max_size + 1,
               1000, 1024, 4096, 5000, 20000]
    streams = [rng.integers(0, 256, n, dtype=np.uint8) for n in lengths]
    streams += [np.zeros(5000, dtype=np.uint8),  # constant: skip-heavy
                (np.arange(7000) % 256).astype(np.uint8),  # monotone sawtooth
                np.tile(np.array([1, 2], dtype=np.uint8), 3000)]  # period-2
    tickets = [sched.submit(s, tag=i) for i, s in enumerate(streams)]
    assert tickets == sorted(tickets)
    results = sched.drain()
    assert [r.tag for r in results] == list(range(len(streams)))
    for r in results:
        d = streams[r.tag]
        want = _exact(d)
        assert r.bounds.tolist() == want, f"stream {r.tag} (n={d.size})"
        assert r.lengths.sum() == d.size
        if d.size:
            np.testing.assert_array_equal(
                r.fps, fingerprints_numpy(d, np.asarray(want))
            )


def test_scheduler_partial_batch_padding(rng):
    """A drained partial bucket dispatches only the rows it has — one
    pending stream ships one device row, not ``slots`` zero-padded rows."""
    sched = ChunkScheduler(P, slots=8, min_bucket=1024)
    d = rng.integers(0, 256, 3000, dtype=np.uint8)
    sched.submit(d)
    (r,) = sched.drain()
    assert r.bounds.tolist() == _exact(d)
    assert sched.stats.padded_rows == 0
    assert sched.stats.device_rows == 1  # exactly the rows needed, no more
    assert sched.stats.dispatches == 1
    # device traffic is one bucket row, not slots-of-them
    assert sched.stats.device_bytes == 3072  # bucket_for(3000) == 3072


def test_scheduler_fills_bucket_dispatches_early(rng):
    # packing off: pins the bucket path's fill-triggered dispatch (under
    # REPRO_PACKING_IMPL=segments these sub-min_bucket streams would
    # queue for a packed row instead)
    sched = ChunkScheduler(P, slots=2, min_bucket=1024, packing_impl="off")
    sched.submit(rng.integers(0, 256, 600, dtype=np.uint8))
    assert sched.stats.dispatches == 0
    sched.submit(rng.integers(0, 256, 900, dtype=np.uint8))
    assert sched.stats.dispatches == 1  # bucket filled: no waiting for drain


# -- service --------------------------------------------------------------------

def _version_corpus(n=6, base=1 << 18, seed=3):
    return list(snapshot_series(base_bytes=base, snapshots=n,
                                edit_rate=2e-5, seed=seed))


def test_roundtrip_and_dedup_ratio():
    """End-to-end acceptance: byte-identical restore, ratio > 1.5x."""
    svc = DedupService(params=P, slots=4, min_bucket=1024)
    versions = _version_corpus()
    for i, v in enumerate(versions):
        svc.submit(f"v{i:03d}", v)
    stats = svc.flush()
    assert len(stats) == len(versions)
    for i, v in enumerate(versions):
        assert svc.get(f"v{i:03d}") == v.tobytes()  # SHA-verified inside
    st = svc.stats()
    assert st.objects == len(versions)
    assert st.logical_bytes == sum(v.size for v in versions)
    assert st.dedup_ratio > 1.5, st.dedup_ratio
    assert st.fp_estimated_savings > 0.5
    assert sum(st.chunk_size_hist.values()) == st.total_chunks


def test_drain_error_does_not_strand_names(rng, monkeypatch):
    """A device-side error during flush loses the in-flight requests; the
    names must not stay blocked for resubmission."""
    svc = DedupService(params=P, slots=8, min_bucket=1024)
    data = rng.integers(0, 256, 2000, dtype=np.uint8)
    svc.submit("x", data)
    monkeypatch.setattr(svc.scheduler, "drain",
                        lambda: (_ for _ in ()).throw(RuntimeError("device")))
    with pytest.raises(RuntimeError):
        svc.flush()
    monkeypatch.undo()
    svc.put("x", data)  # nothing was committed: plain resubmission works
    assert svc.get("x") == data.tobytes()


def test_put_accepts_raw_bytes(rng):
    """The documented contract: raw bytes (and bytearray) ingest directly."""
    svc = DedupService(params=P, slots=2, min_bucket=1024)
    payload = rng.integers(0, 256, 3000, dtype=np.uint8).tobytes()
    svc.put("b", payload)
    assert svc.get("b") == payload
    svc.put("ba", bytearray(payload))
    assert svc.get("ba") == payload


def test_empty_and_tiny_objects():
    svc = DedupService(params=P, slots=2, min_bucket=1024)
    svc.put("empty", np.zeros(0, dtype=np.uint8))
    svc.put("tiny", np.array([7], dtype=np.uint8))
    assert svc.get("empty") == b""
    assert svc.get("tiny") == b"\x07"
    assert svc.stat("empty").chunks == 0
    assert svc.stat("tiny").chunks == 1


def test_duplicate_name_and_overwrite(rng):
    svc = DedupService(params=P, slots=2, min_bucket=1024)
    a = rng.integers(0, 256, 2000, dtype=np.uint8)
    b = rng.integers(0, 256, 2000, dtype=np.uint8)
    svc.put("x", a)
    with pytest.raises(KeyError):
        svc.put("x", b)
    svc.put("x", b, overwrite=True)
    assert svc.get("x") == b.tobytes()
    # the old version's blocks were released
    svc.delete("x")
    assert svc.store.stored_bytes == 0


def test_delete_releases_and_accounting(rng):
    svc = DedupService(params=P, slots=4, min_bucket=1024)
    v1 = rng.integers(0, 256, 20_000, dtype=np.uint8)
    v2 = v1.copy()
    v2[5000:5004] ^= 0xFF
    svc.submit("v1", v1)
    svc.submit("v2", v2)
    svc.flush()
    stored_both = svc.store.stored_bytes
    freed = svc.delete("v2")
    # v2 shares most chunks with v1: deleting frees only the edited ones
    assert 0 < freed < v2.size * 0.5
    assert svc.store.stored_bytes == stored_both - freed
    svc.delete("v1")
    assert svc.store.stored_bytes == 0
    assert svc.store.logical_bytes == 0
    with pytest.raises(KeyError):
        svc.delete("v1")  # unknown object is a client error...
    assert svc.store.release("not-a-key") is False  # ...missing key is not


def test_gc_reclaims_orphans_and_repairs_refs(rng):
    svc = DedupService(params=P, slots=2, min_bucket=1024)
    svc.put("obj", rng.integers(0, 256, 5000, dtype=np.uint8))
    # crash between block write and recipe commit: orphan block, drifted ref
    orphan = svc.store.put(b"orphaned chunk bytes" * 10)
    key0 = svc.recipes.get("obj").keys[0]
    svc.store.refs[key0] += 3  # refcount drift
    g = svc.gc()
    assert g.freed_blocks == 1
    assert g.freed_bytes == 200
    assert g.repaired_refs == 1
    assert orphan not in svc.store
    assert svc.get("obj")  # live data untouched


def test_gc_reclaims_filesystem_orphans(tmp_path, rng):
    """A block file on disk that the manifest never recorded (crash between
    block write and manifest sync) is found and reclaimed by the sweep."""
    root = str(tmp_path / "depot")
    svc = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    svc.put("obj", rng.integers(0, 256, 3000, dtype=np.uint8))
    orphan_path = os.path.join(root, "blocks", "f" * 64)
    with open(orphan_path, "wb") as f:
        f.write(b"x" * 123)
    with open(orphan_path + ".tmp", "wb") as f:
        f.write(b"torn write")
    svc2 = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    g = svc2.gc()
    assert g.freed_blocks == 1 and g.freed_bytes == 123
    assert not os.path.exists(orphan_path)
    assert not os.path.exists(orphan_path + ".tmp")
    assert svc2.get("obj")


def test_gc_readopts_unmanifested_live_blocks(tmp_path, rng):
    """Crash between recipes.json and manifest.json: a live block missing
    from the refcount manifest is re-adopted with consistent accounting."""
    root = str(tmp_path / "depot")
    svc = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    svc.put("obj", rng.integers(0, 256, 3000, dtype=np.uint8))
    key = svc.recipes.get("obj").keys[0]
    full_stored = svc.store.stored_bytes
    # simulate the stale manifest: forget the key, then re-persist
    size = svc.store.chunk_size(key)
    svc.store.stored_bytes -= size
    svc.store.logical_bytes -= size
    del svc.store.refs[key]
    svc.store.sync_manifest()
    svc2 = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    g = svc2.gc()
    assert g.repaired_refs == 1
    assert svc2.store.refs[key] == 1
    assert svc2.store.stored_bytes == full_stored  # re-adopted bytes counted
    assert svc2.get("obj")
    svc2.delete("obj")
    assert svc2.store.stored_bytes == 0 and svc2.store.logical_bytes == 0


def test_delete_is_durable_before_unlink(tmp_path, rng, monkeypatch):
    """Crash mid-delete (after the recipe sync, before block unlink) leaves
    orphan blocks — reclaimable — never a recipe pointing at missing blocks."""
    root = str(tmp_path / "depot")
    svc = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    svc.put("keep", rng.integers(0, 256, 3000, dtype=np.uint8))
    svc.put("gone", rng.integers(0, 256, 3000, dtype=np.uint8))
    monkeypatch.setattr(svc.store, "release",
                        lambda k: (_ for _ in ()).throw(RuntimeError("crash")))
    with pytest.raises(RuntimeError):
        svc.delete("gone")
    svc2 = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    assert svc2.names() == ["keep"]  # recipe removal was durable
    assert svc2.get("keep")
    g = svc2.gc()  # the un-released blocks are orphans now
    assert g.freed_blocks > 0
    svc2.delete("keep")
    svc2.gc()
    assert svc2.store.stored_bytes == 0


def test_stale_manifest_missing_block_recovery(tmp_path, rng):
    """Crash window of delete: block file unlinked, manifest still lists the
    key.  release() replay and gc() must not crash, accounting must settle,
    and re-ingesting identical content must rewrite the missing file."""
    root = str(tmp_path / "depot")
    svc = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    data = rng.integers(0, 256, 3000, dtype=np.uint8)
    svc.put("obj", data)
    key0 = svc.recipes.get("obj").keys[0]
    # simulate the crash: file gone, manifest (already synced) still has it
    os.remove(os.path.join(root, "blocks", key0))

    # 1) re-ingest identical content: the file must be rewritten (a recipe
    #    must never name bytes that are not on disk)
    svc2 = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    svc2.put("obj2", data)
    assert svc2.get("obj2") == data.tobytes()
    assert svc2.get("obj") == data.tobytes()

    # 2) release replay on a manifest-listed key with no file: no crash
    os.remove(os.path.join(root, "blocks", key0))
    svc3 = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    svc3.recipes.remove("obj")
    svc3.recipes.remove("obj2")
    svc3.recipes.sync()
    for k in set([key0] + svc2.recipes.get("obj").keys
                 + svc2.recipes.get("obj2").keys):
        svc3.store.release(k)  # must not raise, file present or not
    svc3.gc()  # sweeps whatever refcounts missed; must not raise either
    assert svc3.store.stored_bytes == 0
    assert svc3.store.logical_bytes == 0


def test_persistence_across_restart(tmp_path, rng):
    root = str(tmp_path / "depot")
    svc = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    versions = _version_corpus(n=3, base=1 << 16)
    for i, v in enumerate(versions):
        svc.submit(f"v{i}", v)
    svc.flush()
    stored = svc.store.stored_bytes

    svc2 = DedupService.open(root, params=P, slots=2, min_bucket=1024)
    assert svc2.names() == [f"v{i}" for i in range(3)]
    for i, v in enumerate(versions):
        assert svc2.get(f"v{i}") == v.tobytes()
    assert svc2.store.stored_bytes == stored
    # incremental run: a near-duplicate new version stores little
    v_new = versions[-1].copy()
    v_new[100:104] ^= 1
    svc2.put("v3", v_new)
    assert svc2.store.stored_bytes - stored < v_new.size * 0.5
    svc2.delete("v3")
    assert svc2.store.stored_bytes == stored


def test_restore_integrity_check(rng):
    svc = DedupService(params=P, slots=2, min_bucket=1024)
    svc.put("obj", rng.integers(0, 256, 3000, dtype=np.uint8))
    r = svc.recipes.get("obj")
    assert isinstance(svc.store, BlockStore)
    svc.store.blocks[r.keys[0]] = b"\x00" * len(svc.store.blocks[r.keys[0]])
    with pytest.raises(IntegrityError):
        svc.get("obj")


# -- estimator CLI --------------------------------------------------------------

def _write_version_files(root, versions):
    os.makedirs(root, exist_ok=True)
    for i, v in enumerate(versions):
        with open(os.path.join(root, f"v{i:03d}.bin"), "wb") as f:
            f.write(v.tobytes())


def test_estimator_cli_on_directory(tmp_path, capsys):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))
    import dedupe_estimate

    corpus_dir = str(tmp_path / "corpus")
    _write_version_files(corpus_dir, _version_corpus(n=4, base=1 << 16))
    rc = dedupe_estimate.main([corpus_dir, "--avg-chunk", "4096",
                               "--slots", "4"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "dedup ratio" in out and "chunk-size distribution" in out
    assert "logical bytes" in out and "stored bytes" in out


def test_estimator_cli_json_and_synthetic(capsys):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))
    import dedupe_estimate

    rc = dedupe_estimate.main(["--synthetic", "4", "--synthetic-mb", "1",
                               "--avg-chunk", "4096", "--json"])
    assert rc == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["objects"] == 4
    assert rep["logical_bytes"] > rep["stored_bytes"]
    assert rep["dedup_ratio"] > 1.5  # version series dedups well
    assert rep["total_chunks"] >= rep["unique_chunks"] > 0
