"""Pallas fingerprint kernel: bit-identity with the reference paths.

The fused kernel (kernels/fingerprint.py) must match both the jnp
gather/segment_sum chain (``fp_impl="reference"``) and the host-side
``fingerprints_numpy`` ground truth bit-for-bit — over random chunkings,
the documented edge cases (empty stream, single max-size 64 KiB chunk, the
65535-byte limb-overflow boundary, count=0 padding rows), the vmapped
scheduler path, and with the first-dispatch divergence guard armed.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hypothesis in this env: deterministic fallback
    from _hyp_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.automaton import max_chunks_for
from repro.core.params import SeqCDCParams
from repro.core.seqcdc import boundaries_two_phase
from repro.dedup.fingerprint import (
    MAX_CHUNK,
    chunk_fingerprints,
    fingerprints_numpy,
)
from repro.kernels.fingerprint import fingerprint_pallas
from repro.service.scheduler import ChunkScheduler, FingerprintDivergenceError

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)

_SENTINEL = 1 << 30  # the automaton's bounds padding past count


def _padded_bounds(cuts: np.ndarray, max_chunks: int) -> np.ndarray:
    out = np.full(max_chunks, _SENTINEL, dtype=np.int32)
    out[: len(cuts)] = cuts
    return out


def _assert_parity(data: np.ndarray, cuts: np.ndarray, max_chunks: int,
                   tile: int = 64 * 1024):
    bounds = jnp.asarray(_padded_bounds(cuts, max_chunks))
    count = jnp.asarray(len(cuts))
    fp_k, len_k = fingerprint_pallas(
        jnp.asarray(data), bounds, count, max_chunks=max_chunks, tile=tile,
        interpret=True,
    )
    fp_r, len_r = chunk_fingerprints(
        jnp.asarray(data), bounds, count, max_chunks=max_chunks,
        fp_impl="reference",
    )
    np.testing.assert_array_equal(np.asarray(fp_k), np.asarray(fp_r))
    np.testing.assert_array_equal(np.asarray(len_k), np.asarray(len_r))
    want = fingerprints_numpy(data, cuts)
    np.testing.assert_array_equal(np.asarray(fp_k)[: len(cuts)], want)


def _random_cuts(rng, n: int, max_len: int = MAX_CHUNK) -> np.ndarray:
    cuts = []
    s = 0
    while s < n:
        s = min(n, s + int(rng.integers(1, max_len + 1)))
        cuts.append(s)
    return np.asarray(cuts, dtype=np.int64)


@pytest.mark.parametrize("n", [1, 2, 100, 1023, 1024, 1025, 4096, 70000])
def test_fingerprint_kernel_random_chunkings(n, rng):
    data = rng.integers(0, 256, n, dtype=np.uint8)
    cuts = _random_cuts(rng, n, max_len=max(1, n // 3))
    _assert_parity(data, cuts, max_chunks=len(cuts) + 3)


@pytest.mark.parametrize("tile", [1024, 4096, 64 * 1024])
def test_fingerprint_kernel_tile_sweep(tile, rng):
    data = rng.integers(0, 256, 50_000, dtype=np.uint8)
    cuts = _random_cuts(rng, data.size, max_len=9000)
    _assert_parity(data, cuts, max_chunks=len(cuts) + 2, tile=tile)


@pytest.mark.parametrize("n", [65535, 65536])
def test_fingerprint_kernel_single_max_chunk(n, rng):
    """One chunk at/next to the 64 KiB power-table and limb bound."""
    data = rng.integers(0, 256, n, dtype=np.uint8)
    _assert_parity(data, np.array([n], dtype=np.int64), max_chunks=4)


def test_fingerprint_kernel_limb_boundary():
    """All-0xFF 65535/65536-byte chunks maximize the 16-bit limb sums —
    the exactness bound of the in-kernel cumsum reduction."""
    data = np.full(65536 + 65535, 0xFF, dtype=np.uint8)
    cuts = np.array([65536, 65536 + 65535], dtype=np.int64)
    _assert_parity(data, cuts, max_chunks=5)


def test_fingerprint_kernel_empty_stream():
    fp, lens = fingerprint_pallas(
        jnp.zeros((0,), jnp.uint8), jnp.full((4,), _SENTINEL, jnp.int32),
        jnp.asarray(0), max_chunks=4, interpret=True,
    )
    assert fp.shape == (4, 2) and not np.asarray(fp).any()
    assert lens.shape == (4,) and not np.asarray(lens).any()


def test_fingerprint_kernel_count_zero_padding_row(rng):
    """A scheduler zero-padding row: data present, count = 0 — every slot
    must come back zeroed exactly like the reference."""
    data = np.zeros(4096, dtype=np.uint8)
    bounds = jnp.asarray(np.array([4096, _SENTINEL, _SENTINEL, _SENTINEL],
                                  dtype=np.int32))
    for impl in ("reference", "pallas"):
        fp, lens = chunk_fingerprints(
            jnp.asarray(data), bounds, jnp.asarray(0), max_chunks=4,
            fp_impl=impl,
        )
        assert not np.asarray(fp).any() and not np.asarray(lens).any()


@settings(max_examples=25, deadline=None)
@given(data=st.binary(min_size=1, max_size=3000), avg=st.integers(5, 60))
def test_property_fingerprint_kernel(data, avg):
    arr = np.frombuffer(data, dtype=np.uint8)
    rng = np.random.default_rng(len(data) * 31 + avg)
    cuts = _random_cuts(rng, arr.size, max_len=max(1, avg))
    _assert_parity(arr, cuts, max_chunks=len(cuts) + 2)


def test_chunker_bounds_layout_parity(rng):
    """Parity on real SeqCDC output (sentinel padding, final cut at n)."""
    data = rng.integers(0, 256, 30_000, dtype=np.uint8)
    b, c = boundaries_two_phase(jnp.asarray(data), P)
    mc = max_chunks_for(data.size, P)
    fp_k, len_k = fingerprint_pallas(jnp.asarray(data), b, c, max_chunks=mc,
                                     interpret=True)
    fp_r, len_r = chunk_fingerprints(jnp.asarray(data), b, c, max_chunks=mc)
    np.testing.assert_array_equal(np.asarray(fp_k), np.asarray(fp_r))
    np.testing.assert_array_equal(np.asarray(len_k), np.asarray(len_r))


# -- the scheduler hot path -----------------------------------------------------

def test_scheduler_fp_pallas_bit_identity(rng):
    """fp_impl='pallas' with the cross-check armed: results identical to the
    reference scheduler, and the first-dispatch guard actually ran."""
    sched = ChunkScheduler(P, slots=2, min_bucket=1024, fp_impl="pallas",
                           cross_check_fps=True)
    ref = ChunkScheduler(P, slots=2, min_bucket=1024)
    streams = [rng.integers(0, 256, n, dtype=np.uint8)
               for n in (100, 1000, 1024, 3000, 5000)]
    for i, s in enumerate(streams):
        sched.submit(s, tag=i)
        ref.submit(s, tag=i)
    got = {r.tag: r for r in sched.drain()}
    for r in ref.drain():
        assert got[r.tag].bounds.tolist() == r.bounds.tolist()
        np.testing.assert_array_equal(got[r.tag].fps, r.fps)
    assert sched._fp_checked_buckets  # the guard actually ran


def test_fingerprint_divergence_raises(rng, monkeypatch):
    """The guard fires when a corrupted kernel result is injected: the
    cross-check's replay sees fingerprints that differ from the dispatch."""
    import repro.service.scheduler as sched_mod

    # packing off: this pins the *bucket* path's guard, which fires at
    # submit time (under REPRO_PACKING_IMPL=segments a 900-byte stream
    # would queue for a packed row instead)
    sched = ChunkScheduler(P, slots=1, min_bucket=1024, fp_impl="reference",
                           cross_check_fps=True, packing_impl="off")
    real = sched_mod.chunk_fingerprints

    def lying(data, b, c, **kw):
        fp, lens = real(data, b, c, **kw)
        if kw.get("fp_impl") == "pallas":  # corrupt only the kernel path
            return fp ^ 1, lens  # flip one bit of every fingerprint
        return fp, lens

    monkeypatch.setattr(sched_mod, "chunk_fingerprints", lying)
    with pytest.raises(FingerprintDivergenceError):
        sched.submit(rng.integers(0, 256, 900, dtype=np.uint8))


def test_unknown_fp_impl_rejected(rng):
    data = rng.integers(0, 256, 100, dtype=np.uint8)
    with pytest.raises(ValueError):
        chunk_fingerprints(jnp.asarray(data),
                           jnp.asarray(np.array([100], dtype=np.int32)),
                           jnp.asarray(1), max_chunks=1, fp_impl="bogus")
