"""Sharded dedup service: N-vs-1 equivalence, async flush crash safety,
owner-local GC, all_to_all fp routing, and the Pallas hot-path guard.

Acceptance coverage (ISSUE 2): an N-shard ingest of a corpus yields
*identical* dedup byte totals and *byte-identical* restores to the 1-shard
service with async flush on; a crash between block write and manifest write
leaves reclaimable orphans and zero corrupt manifests.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hypothesis in this env: deterministic fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.core.params import SeqCDCParams
from repro.data.corpus import snapshot_series
from repro.dedup import BlockStore
from repro.service import (
    AsyncWriteError,
    DedupService,
    IntegrityError,
    MaskDivergenceError,
    ShardedDedupService,
    ShardWriter,
    WriterPool,
)
from repro.service.scheduler import ChunkScheduler

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _corpus(seed: int, versions: int = 4, base: int = 1 << 16):
    """Version series + a few unrelated streams: dedup-heavy mixed traffic."""
    rng = np.random.default_rng(seed)
    objs = list(snapshot_series(base_bytes=base, snapshots=versions,
                                edit_rate=3e-5, seed=seed))
    objs.append(rng.integers(0, 256, int(rng.integers(1, 5000)), dtype=np.uint8))
    objs.append(np.zeros(0, dtype=np.uint8))  # empty object
    return objs


def _ingest(svc, objs):
    for i, o in enumerate(objs):
        svc.submit(f"o{i:03d}", o)
    svc.flush()


# -- N-vs-1 equivalence (the acceptance property) -------------------------------

@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_sharded_equals_single_property(seed):
    """Property: for N in {1,2,4}, byte totals identical and restores
    byte-identical to the single-store service, async flush on."""
    objs = _corpus(seed)
    single = DedupService(params=P, slots=4, min_bucket=1024)
    _ingest(single, objs)
    want = single.stats()
    restores = {f"o{i:03d}": single.get(f"o{i:03d}") for i in range(len(objs))}

    for n in (1, 2, 4):
        svc = ShardedDedupService(n, params=P, slots=4, min_bucket=1024,
                                  async_flush=True)
        _ingest(svc, objs)
        got = svc.stats()
        assert got.stored_bytes == want.stored_bytes, f"N={n}"
        assert got.logical_bytes == want.logical_bytes, f"N={n}"
        assert got.unique_chunks == want.unique_chunks, f"N={n}"
        assert got.total_chunks == want.total_chunks, f"N={n}"
        assert got.fp_estimated_savings == pytest.approx(
            want.fp_estimated_savings, abs=1e-12), f"N={n}"
        for name, data in restores.items():
            assert svc.get(name) == data, f"N={n} {name}"
        svc.close()


def test_shards_actually_partition():
    """With N=4 the unique chunks spread over all shards (not one hot shard),
    and per-shard uniques sum to the global count."""
    svc = ShardedDedupService(4, params=P, slots=4, min_bucket=1024)
    _ingest(svc, _corpus(7, versions=3, base=1 << 17))
    per = svc.shard_stats()
    assert sum(s["unique_chunks"] for s in per) == svc.stats().unique_chunks
    populated = [s for s in per if s["unique_chunks"] > 0]
    assert len(populated) == 4, per
    svc.close()


def test_delete_overwrite_and_gc_across_shards(rng):
    svc = ShardedDedupService(4, params=P, slots=4, min_bucket=1024)
    v1 = rng.integers(0, 256, 20_000, dtype=np.uint8)
    v2 = v1.copy()
    v2[4000:4004] ^= 0xFF
    svc.put("a", v1)
    svc.put("a", v2, overwrite=True)  # old version's blocks released
    assert svc.get("a") == v2.tobytes()
    svc.put("b", v1)
    freed = svc.delete("b")
    assert 0 < freed < v1.size  # shares most chunks with the overwritten "a"
    svc.delete("a")
    assert all(s.stored_bytes == 0 and s.logical_bytes == 0 for s in svc.stores)
    with pytest.raises(KeyError):
        svc.delete("a")
    svc.close()


def test_single_store_recipe_opens_at_one_shard(rng):
    """Migration: recipes without a shard map restore at N=1, error at N>1."""
    single = DedupService(params=P, slots=2, min_bucket=1024)
    data = rng.integers(0, 256, 3000, dtype=np.uint8)
    single.put("x", data)
    svc = ShardedDedupService(1, stores=[single.store], params=P,
                              recipes=single.recipes, min_bucket=1024)
    assert svc.get("x") == data.tobytes()
    svc4 = ShardedDedupService(4, params=P, min_bucket=1024)
    svc4.recipes.add(single.recipes.get("x"))
    with pytest.raises(IntegrityError):
        svc4.get("x")


# -- async flush: ordering and crash injection ----------------------------------

def test_async_backpressure_tiny_queue():
    """max_pending=1 forces constant producer/consumer handoff; results
    must be unaffected."""
    objs = _corpus(11, versions=3)
    a = ShardedDedupService(2, params=P, slots=4, min_bucket=1024,
                            async_flush=True, max_pending=1)
    b = ShardedDedupService(2, params=P, slots=4, min_bucket=1024,
                            async_flush=False)
    _ingest(a, objs)
    _ingest(b, objs)
    assert a.stats().stored_bytes == b.stats().stored_bytes
    for i in range(len(objs)):
        assert a.get(f"o{i:03d}") == b.get(f"o{i:03d}")
    a.close()
    b.close()


def test_crash_between_block_and_manifest_write(tmp_path, rng, monkeypatch):
    """The issue's crash injection: blocks durably land, then the process
    dies before recipes/manifests are written.  On restart: committed
    objects intact, no corrupt manifests, GC reclaims every orphan."""
    root = str(tmp_path / "depot")
    svc = ShardedDedupService.open(root, 2, params=P, slots=2, min_bucket=1024)
    keep = rng.integers(0, 256, 8000, dtype=np.uint8)
    svc.put("keep", keep)
    stored_committed = sum(s.stored_bytes for s in svc.stores)

    # kill after the writer barrier (blocks on disk) and before any
    # recipe/manifest sync
    monkeypatch.setattr(svc.recipes, "sync",
                        lambda: (_ for _ in ()).throw(RuntimeError("crash")))
    svc.submit("lost", rng.integers(0, 256, 8000, dtype=np.uint8))
    with pytest.raises(RuntimeError):
        svc.flush()
    # the new object's blocks exist on disk but no manifest/recipe names them
    on_disk = sum(len(s.scan_keys()) for s in svc.stores)
    assert on_disk > len(svc.recipes.get("keep").keys)
    svc.close()

    svc2 = ShardedDedupService.open(root, 2, params=P, slots=2, min_bucket=1024)
    assert svc2.names() == ["keep"]  # no torn recipe
    assert svc2.get("keep") == keep.tobytes()
    g = svc2.gc()
    assert g.freed_blocks > 0  # the orphaned blocks of "lost"
    assert sum(s.stored_bytes for s in svc2.stores) == stored_committed
    svc2.delete("keep")
    svc2.gc()
    assert all(s.stored_bytes == 0 for s in svc2.stores)
    svc2.close()


def test_failed_block_write_aborts_before_recipe_commit(rng, monkeypatch):
    """A write error inside the async queue surfaces as AsyncWriteError at
    the flush barrier, *before* any recipe is committed — and the name is
    not stranded in the in-flight set (resubmission must work)."""
    svc = ShardedDedupService(2, params=P, slots=2, min_bucket=1024,
                              async_flush=True)
    data = rng.integers(0, 256, 5000, dtype=np.uint8)
    puts = [svc.stores[0].put, svc.stores[1].put]
    boom = lambda chunk: (_ for _ in ()).throw(OSError("disk gone"))
    monkeypatch.setattr(svc.stores[0], "put", boom)
    monkeypatch.setattr(svc.stores[1], "put", boom)
    svc.submit("x", data)
    with pytest.raises(AsyncWriteError):
        svc.flush()
    assert len(svc.recipes) == 0  # nothing committed
    # "disk" recovers: the failed flush must not block resubmitting "x"
    monkeypatch.setattr(svc.stores[0], "put", puts[0])
    monkeypatch.setattr(svc.stores[1], "put", puts[1])
    svc.put("x", data)
    assert svc.get("x") == data.tobytes()
    svc.close()


def test_flush_coalesces_put_blocks(rng):
    """The flush hot path batches each shard's chunk puts into
    ``put_blocks`` calls — one per shard per flush below the byte cap —
    instead of one ``put`` per chunk, and the result is byte-identical."""
    calls = []

    class CountingStore(BlockStore):
        def put_blocks(self, chunks):
            chunks = list(chunks)  # materialize once: the surface is Iterable
            calls.append(len(chunks))
            return super().put_blocks(chunks)

    stores = [CountingStore() for _ in range(2)]
    svc = ShardedDedupService(2, stores=stores, params=P, slots=4,
                              min_bucket=1024)
    data = [rng.integers(0, 256, n, dtype=np.uint8) for n in (5000, 3000, 2000)]
    for i, d in enumerate(data):
        svc.submit(f"o{i}", d)
    svc.flush()
    total_chunks = sum(len(svc.recipes.get(f"o{i}").keys)
                      for i in range(len(data)))
    assert len(calls) <= 2  # at most one batch per shard for a small flush
    assert sum(calls) == total_chunks
    for i, d in enumerate(data):
        assert svc.get(f"o{i}") == d.tobytes()
    svc.close()


def test_shard_writer_unit():
    """ShardWriter: FIFO execution, error capture, sync mode, pool barrier."""
    order = []
    w = ShardWriter(max_pending=2)
    for i in range(10):
        w.submit(lambda i=i: order.append(i))
    w.barrier()
    assert order == list(range(10))  # FIFO, all ran
    w.submit(lambda: (_ for _ in ()).throw(ValueError("x")))
    with pytest.raises(AsyncWriteError):
        w.barrier()
    w.barrier()  # error consumed; queue healthy again
    w.close()

    ran = []
    sync = ShardWriter(max_pending=0)  # inline mode
    sync.submit(lambda: ran.append(1))
    assert ran == [1]
    sync.barrier()
    sync.close()

    pool = WriterPool(3, max_pending=4)
    hits = [0, 0, 0]
    for s in range(3):
        pool.submit(s, lambda s=s: hits.__setitem__(s, hits[s] + 1))
    pool.barrier()
    assert hits == [1, 1, 1]
    pool.close()


# -- persistence ----------------------------------------------------------------

def test_sharded_persistence_and_shard_count_pin(tmp_path, rng):
    root = str(tmp_path / "depot")
    versions = list(snapshot_series(base_bytes=1 << 16, snapshots=3,
                                    edit_rate=2e-5, seed=9))
    svc = ShardedDedupService.open(root, 4, params=P, slots=4, min_bucket=1024)
    _ingest(svc, versions)
    stored = sum(s.stored_bytes for s in svc.stores)
    svc.close()

    with pytest.raises(ValueError):  # reopening with a different N is refused
        ShardedDedupService.open(root, 2, params=P)

    svc2 = ShardedDedupService.open(root, 4, params=P, slots=4, min_bucket=1024)
    for i, v in enumerate(versions):
        assert svc2.get(f"o{i:03d}") == v.tobytes()
    assert sum(s.stored_bytes for s in svc2.stores) == stored
    svc2.close()


# -- Pallas hot path ------------------------------------------------------------

def test_scheduler_pallas_bit_identity(rng):
    """mask_impl='pallas' with the cross-check on: every first-dispatch-per-
    bucket batch is replayed through the lax path and must match bit-for-bit
    (it does; a divergence would raise MaskDivergenceError)."""
    sched = ChunkScheduler(P, slots=2, min_bucket=1024, mask_impl="pallas",
                           cross_check_masks=True)
    ref = ChunkScheduler(P, slots=2, min_bucket=1024, mask_impl="jnp")
    streams = [rng.integers(0, 256, n, dtype=np.uint8)
               for n in (100, 1000, 1024, 3000, 5000)]
    for i, s in enumerate(streams):
        sched.submit(s, tag=i)
        ref.submit(s, tag=i)
    got = {r.tag: r for r in sched.drain()}
    for r in ref.drain():
        assert got[r.tag].bounds.tolist() == r.bounds.tolist()
        np.testing.assert_array_equal(got[r.tag].fps, r.fps)
    assert sched._checked_buckets  # the guard actually ran


def test_mask_divergence_raises(rng, monkeypatch):
    """The guard fires when the two backends disagree (simulated)."""
    import repro.core.seqcdc as seqcdc_mod
    # packing off: pins the *bucket* path's guard, which fires at submit
    # time (under REPRO_PACKING_IMPL=segments the 900-byte stream would
    # queue for a packed row instead)
    sched = ChunkScheduler(P, slots=1, min_bucket=1024, mask_impl="jnp",
                           cross_check_masks=True, packing_impl="off")
    real = seqcdc_mod.boundaries_batch

    def lying(data, p, **kw):
        b, c = real(data, p, **kw)
        return b, c + 1  # claim one extra chunk per row

    monkeypatch.setattr(seqcdc_mod, "boundaries_batch", lying)
    with pytest.raises(MaskDivergenceError):
        sched.submit(rng.integers(0, 256, 900, dtype=np.uint8))


# -- mesh all_to_all routing (subprocess: fixed device count) -------------------

@pytest.mark.timeout(600)
def test_mesh_routed_ingest_matches_host():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import numpy as np, jax
            from repro.core.params import SeqCDCParams
            from repro.data.corpus import snapshot_series
            from repro.service import DedupService, ShardedDedupService

            P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6,
                             skip_size=32, min_size=64, max_size=512)
            mesh = jax.make_mesh((4,), ("data",))
            versions = list(snapshot_series(base_bytes=1 << 16, snapshots=3,
                                            edit_rate=2e-5, seed=5))
            single = DedupService(params=P, slots=4, min_bucket=1024)
            svc = ShardedDedupService(4, params=P, slots=4, min_bucket=1024,
                                      mesh=mesh, capacity_factor=4.0)
            for i, v in enumerate(versions):
                single.submit(f"v{i}", v)
                svc.submit(f"v{i}", v)
            single.flush(); svc.flush()
            assert svc.overflow_rerouted == 0
            a, b = svc.stats(), single.stats()
            assert a.fp_estimated_savings == b.fp_estimated_savings
            assert a.stored_bytes == b.stored_bytes
            assert all(svc.get(f"v{i}") == v.tobytes()
                       for i, v in enumerate(versions))
            svc.close()
            print("OK")
        """)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK" in out.stdout
