"""Fused single-dispatch pipeline kernel: bit-identity with the split path.

The fused kernel (kernels/fused_pipeline.py) collapses the three-dispatch
chunk+fingerprint pipeline into one ``pallas_call``; its contract is
bit-identity with the composed split path (``kernels/ref.fused_pipeline``)
across bounds, counts, fingerprints and lengths — over random streams, the
documented edge regimes (max-size-forced cuts, the 64 KiB limb boundary,
skip overshoots that spill bounds past a tile, file-end cuts behind the
scan position, empty/1-byte streams), tile sweeps, the scheduler hot path,
and with the first-dispatch ``PipelineDivergenceError`` guard armed.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hypothesis in this env: deterministic fallback
    from _hyp_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.automaton import max_chunks_for
from repro.core.params import SeqCDCParams, derived_params
from repro.kernels import ref
from repro.kernels.fused_pipeline import fused_pipeline, fused_pipeline_batch
from repro.service.scheduler import ChunkScheduler, PipelineDivergenceError

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)
P5 = SeqCDCParams(avg_size=256, seq_length=5, skip_trigger=6, skip_size=32,
                  min_size=64, max_size=512)
#: skip_size wider than the smallest tile: overshooting skips resolved as
#: cuts emit bounds several tiles ahead of the firing block
P_SKID = SeqCDCParams(avg_size=4096, seq_length=5, skip_trigger=3,
                      skip_size=3000, min_size=2048, max_size=8192)

_SENTINEL = 1 << 30


def _assert_parity(d2: np.ndarray, p: SeqCDCParams, tile: int = 32 * 1024):
    mc = max_chunks_for(d2.shape[-1], p)
    x = jnp.asarray(d2)
    want = ref.fused_pipeline(x, p, max_chunks=mc)
    got = fused_pipeline_batch(x, p, max_chunks=mc, tile=tile, interpret=True)
    for g, w, name in zip(got, want, ("bounds", "counts", "fps", "lengths")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{name} diverged")


@pytest.mark.parametrize("n", [1, 2, 63, 100, 1000, 5000, 33000, 70000])
def test_fused_pipeline_random(n, rng):
    _assert_parity(rng.integers(0, 256, (2, n), dtype=np.uint8), P)


def test_fused_pipeline_forced_max_size_cuts():
    """Constant bytes never form a monotone run: every cut is a max-size
    cut, the automaton's scan position leapfrogs whole tiles."""
    _assert_parity(np.zeros((2, 20000), dtype=np.uint8), P)


def test_fused_pipeline_decreasing_mode(rng):
    pd = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6,
                      skip_size=32, min_size=64, max_size=512,
                      mode="decreasing")
    _assert_parity(rng.integers(0, 256, (2, 20000), dtype=np.uint8), pd)


@pytest.mark.parametrize("tile", [1024, 4096, 32 * 1024])
def test_fused_pipeline_tile_sweep(tile, rng):
    _assert_parity(rng.integers(0, 256, (2, 20000), dtype=np.uint8), P5,
                   tile=tile)


def test_fused_pipeline_skip_overshoot_spill(rng):
    """skip_size 3000 against 1024-byte tiles: overshooting skips resolved
    as cuts (_resolve's trig_cuts) emit bounds far past the firing tile,
    exercising the wide halo and the behind-the-tile file-end factor."""
    _assert_parity(rng.integers(0, 256, (2, 30000), dtype=np.uint8), P_SKID,
                   tile=1024)
    _assert_parity(rng.integers(0, 4, (2, 30000), dtype=np.uint8), P_SKID,
                   tile=1024)


def test_fused_pipeline_limb_boundary():
    """All-0xFF bytes at max_size 64 KiB: maximal 16-bit limb sums and
    chunk lengths at the power-table bound, the exactness edge."""
    p64 = derived_params(32768)
    assert p64.max_size == 65536
    _assert_parity(np.full((1, 65536 + 65535), 0xFF, dtype=np.uint8), p64)


def test_fused_pipeline_empty_and_single_byte(rng):
    b, c, f, ln = fused_pipeline_batch(
        jnp.zeros((2, 0), jnp.uint8), P, max_chunks=3, interpret=True)
    assert np.asarray(c).tolist() == [0, 0]
    assert (np.asarray(b) == _SENTINEL).all()
    assert not np.asarray(f).any() and not np.asarray(ln).any()
    _assert_parity(rng.integers(0, 256, (1, 1), dtype=np.uint8), P)


def test_fused_pipeline_single_stream_wrapper(rng):
    d = rng.integers(0, 256, 5000, dtype=np.uint8)
    mc = max_chunks_for(d.size, P)
    b1, c1, f1, l1 = fused_pipeline(jnp.asarray(d), P, max_chunks=mc)
    b2, c2, f2, l2 = fused_pipeline_batch(jnp.asarray(d)[None], P,
                                          max_chunks=mc, interpret=True)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2)[0])
    assert int(c1) == int(np.asarray(c2)[0])
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2)[0])
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2)[0])


@settings(max_examples=15, deadline=None)
@given(data=st.binary(min_size=1, max_size=4000),
       rep=st.integers(1, 8))
def test_property_fused_pipeline(data, rep):
    arr = np.frombuffer(data, dtype=np.uint8)
    arr = np.tile(arr, rep)[:6000]
    _assert_parity(arr[None], P)


# -- the scheduler hot path -----------------------------------------------------

def test_scheduler_fused_bit_identity(rng):
    """pipeline_impl='fused' with the guard armed: results identical to the
    split scheduler, and the first-dispatch cross-check actually ran."""
    sched = ChunkScheduler(P, slots=2, min_bucket=1024,
                           pipeline_impl="fused", cross_check_pipeline=True)
    split = ChunkScheduler(P, slots=2, min_bucket=1024,
                           pipeline_impl="split")
    streams = [rng.integers(0, 256, n, dtype=np.uint8)
               for n in (0, 1, 100, 1000, 1024, 3000, 5000)]
    for i, s in enumerate(streams):
        sched.submit(s, tag=i)
        split.submit(s, tag=i)
    got = {r.tag: r for r in sched.drain()}
    for r in split.drain():
        assert got[r.tag].bounds.tolist() == r.bounds.tolist()
        np.testing.assert_array_equal(got[r.tag].fps, r.fps)
        np.testing.assert_array_equal(got[r.tag].lengths, r.lengths)
    assert sched._pipeline_checked_buckets  # the guard actually ran


def test_scheduler_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_PIPELINE_IMPL", "fused")
    assert ChunkScheduler(P, min_bucket=1024).pipeline_impl == "fused"
    monkeypatch.delenv("REPRO_PIPELINE_IMPL")
    assert ChunkScheduler(P, min_bucket=1024).pipeline_impl == "split"


def test_unknown_pipeline_impl_rejected():
    with pytest.raises(ValueError):
        ChunkScheduler(P, min_bucket=1024, pipeline_impl="bogus")


# -- divergence injection: the guard names the stage that broke -----------------

def _corrupting_scheduler():
    """split dispatch + armed pipeline guard: the guard replays the fused
    path via scheduler._run_fused, which the tests below corrupt."""
    # packing off: these tests pin the *bucket* path's guard, which fires
    # at submit time (under REPRO_PACKING_IMPL=segments the 900-byte
    # stream would queue for a packed row instead)
    return ChunkScheduler(P, slots=1, min_bucket=1024, pipeline_impl="split",
                          cross_check_pipeline=True, packing_impl="off")


def test_pipeline_divergence_boundary_stage(rng, monkeypatch):
    """Corrupt the fused kernel's boundary lane: the error must say the
    boundary stage diverged."""
    import repro.service.scheduler as sched_mod

    real = sched_mod._run_fused

    def lying(x, p, mc):
        b, c, f, ln = real(x, p, mc)
        return b + (b < _SENTINEL), c, f, ln  # shift every real bound by 1

    monkeypatch.setattr(sched_mod, "_run_fused", lying)
    sched = _corrupting_scheduler()
    with pytest.raises(PipelineDivergenceError) as ei:
        sched.submit(rng.integers(0, 256, 900, dtype=np.uint8))
    assert ei.value.stage == "boundaries"
    assert "boundary" in str(ei.value)


def test_pipeline_divergence_fingerprint_stage(rng, monkeypatch):
    """Corrupt only the hash limb path (boundaries intact): the error must
    say the fingerprint stage diverged."""
    import repro.service.scheduler as sched_mod

    real = sched_mod._run_fused

    def lying(x, p, mc):
        b, c, f, ln = real(x, p, mc)
        return b, c, f ^ 1, ln  # flip one bit of every fingerprint

    monkeypatch.setattr(sched_mod, "_run_fused", lying)
    sched = _corrupting_scheduler()
    with pytest.raises(PipelineDivergenceError) as ei:
        sched.submit(rng.integers(0, 256, 900, dtype=np.uint8))
    assert ei.value.stage == "fingerprints"
    assert "fingerprint" in str(ei.value)


def test_pipeline_guard_passes_clean(rng):
    """No corruption: the armed guard replays the fused path and agrees."""
    sched = _corrupting_scheduler()
    sched.submit(rng.integers(0, 256, 900, dtype=np.uint8))
    sched.drain()
    assert sched._pipeline_checked_buckets
