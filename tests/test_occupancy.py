"""Scheduler occupancy regression pins (from bench_scheduler_occupancy).

The occupancy benchmark exposed the scheduler's adversarial regimes —
most notably the all-tiny mix riding the ``min_bucket`` floor at ~96% pad
waste (ROADMAP: "scheduler occupancy fixes for the all-tiny regime").
This file turns those numbers into a regression test: the known-bad
regime is *pinned* inside a band, so a future sub-bucket row-packing fix
shows up as a loud (and welcome) assertion failure here and gets the pin
moved, while an accidental regression of the good regimes fails the floor
assertions.  The benchmark itself is imported and run at the quick budget
(seeded draws: the numbers are deterministic on a given machine).
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_scheduler_occupancy import DISTRIBUTIONS, run


@pytest.fixture(scope="module")
def occupancy_rows():
    rows = run(budget="quick")
    return {r["dist"]: r for r in rows}


def test_all_distributions_reported(occupancy_rows):
    assert set(occupancy_rows) == set(DISTRIBUTIONS)


def test_all_tiny_regime_pinned(occupancy_rows):
    """The known-bad bucket-floor regime: ~96% of device bytes are padding
    because a few-hundred-byte stream pays for a min_bucket row.  Pinned
    in a band — if sub-bucket packing lands, this is the test that moves.
    """
    r = occupancy_rows["all_tiny"]
    assert 92.0 <= r["pad_waste_pct"] <= 99.5, r["pad_waste_pct"]
    # the waste is *length* padding, not empty rows: rows are ~all filled,
    # and every stream is shorter than a full max_size window, so the
    # exact tail redo covers 100% of payload bytes
    assert r["row_fill"] > 0.95, r["row_fill"]
    assert r["tail_pct"] == pytest.approx(100.0), r["tail_pct"]
    assert r["buckets"] == 1  # everything lands on the min_bucket floor


def test_uniform_control_regime(occupancy_rows):
    """The distribution batching likes must stay decent: a drop below the
    floor means a scheduler regression, not workload noise."""
    r = occupancy_rows["uniform"]
    assert r["occupancy"] >= 0.55, r["occupancy"]
    assert r["row_fill"] >= 0.6, r["row_fill"]


def test_regime_ordering(occupancy_rows):
    """Relative shape of the curve: uniform beats the adversarial mixes,
    and all_tiny is the worst of them all."""
    occ = {d: r["occupancy"] for d, r in occupancy_rows.items()}
    assert occ["uniform"] > occ["bimodal"]
    assert occ["uniform"] > occ["heavy_tail"]
    assert occ["all_tiny"] == min(occ.values())
    assert occ["all_tiny"] < 0.10  # the floor regime is far from fixed


def test_device_bytes_account_for_padding(occupancy_rows):
    """occupancy == stream/device bytes by construction; the two byte
    counters must stay consistent with the reported ratio."""
    for dist, r in occupancy_rows.items():
        assert r["device_mb"] >= r["stream_mb"], dist
        assert r["occupancy"] == pytest.approx(
            r["stream_mb"] / r["device_mb"]), dist
