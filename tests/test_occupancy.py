"""Scheduler occupancy regression pins (from bench_scheduler_occupancy).

The occupancy benchmark exposed the scheduler's adversarial regimes —
most notably the all-tiny mix riding the ``min_bucket`` floor at ~96% pad
waste (ROADMAP: "scheduler occupancy fixes for the all-tiny regime").
This file turns those numbers into a regression test, in both packing
modes: with ``packing_impl="off"`` the known-bad floor regime is *pinned*
inside a band (so it stays visible as the baseline the packing layer is
measured against), and with ``packing_impl="segments"`` the rescue is
pinned as a floor — all-tiny occupancy must stay >= 0.60 (it lands near
0.9), at least 5x the unpacked baseline, with a zero host-tail redo
because packed results are exact.  The benchmark itself is imported and
run at the quick budget (seeded draws: the numbers are deterministic on a
given machine).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_scheduler_occupancy import DISTRIBUTIONS, run


@pytest.fixture(scope="module")
def occupancy_rows():
    rows = run(budget="quick")
    return {(r["dist"], r["packing_impl"]): r for r in rows}


def test_all_distributions_reported(occupancy_rows):
    want = {(d, mode) for d in DISTRIBUTIONS for mode in ("off", "segments")}
    assert set(occupancy_rows) == want


def test_all_tiny_regime_pinned(occupancy_rows):
    """The known-bad bucket-floor regime (packing off): ~96% of device
    bytes are padding because a few-hundred-byte stream pays for a
    min_bucket row.  Pinned in a band as the baseline segment packing is
    judged against."""
    r = occupancy_rows[("all_tiny", "off")]
    assert 92.0 <= r["pad_waste_pct"] <= 99.5, r["pad_waste_pct"]
    # the waste is *length* padding, not empty rows: rows are ~all filled,
    # and every stream is shorter than a full max_size window, so the
    # exact tail redo covers 100% of payload bytes
    assert r["row_fill"] > 0.95, r["row_fill"]
    assert r["tail_pct"] == pytest.approx(100.0), r["tail_pct"]
    assert r["buckets"] == 1  # everything lands on the min_bucket floor
    assert r["packed_streams"] == 0  # packing off: nothing shares a row


def test_all_tiny_packed_rescued(occupancy_rows):
    """Segment packing is the fix for the floor regime: all-tiny streams
    share min_bucket rows back to back, so occupancy must clear 0.60 (vs
    ~0.03 unpacked — at least a 5x recovery) and the host tail redo
    disappears entirely (packed results are exact by construction)."""
    off = occupancy_rows[("all_tiny", "off")]
    on = occupancy_rows[("all_tiny", "segments")]
    assert on["occupancy"] >= 0.60, on["occupancy"]
    assert on["occupancy"] >= 5.0 * off["occupancy"], (
        on["occupancy"], off["occupancy"])
    assert on["tail_pct"] == 0.0, on["tail_pct"]
    assert on["packed_streams"] == on["streams"]  # every stream packed
    # device traffic shrank by more than an order of magnitude
    assert on["device_mb"] * 10 < off["device_mb"]


def test_uniform_control_regime(occupancy_rows):
    """The distribution batching likes must stay decent in both modes: a
    drop below the floor means a scheduler regression, not workload
    noise."""
    for mode in ("off", "segments"):
        r = occupancy_rows[("uniform", mode)]
        assert r["occupancy"] >= 0.55, (mode, r["occupancy"])
        assert r["row_fill"] >= 0.6, (mode, r["row_fill"])


def test_regime_ordering(occupancy_rows):
    """Relative shape of the unpacked curve: uniform beats the adversarial
    mixes, and all_tiny is the worst of them all."""
    occ = {d: occupancy_rows[(d, "off")]["occupancy"] for d in DISTRIBUTIONS}
    assert occ["uniform"] > occ["bimodal"]
    assert occ["uniform"] > occ["heavy_tail"]
    assert occ["all_tiny"] == min(occ.values())
    assert occ["all_tiny"] < 0.10  # the unpacked floor regime stays bad


def test_packing_never_hurts(occupancy_rows):
    """Turning packing on must not cost occupancy on any distribution:
    streams at or above min_bucket take the bucket path unchanged, and
    sub-bucket streams only get denser."""
    for d in DISTRIBUTIONS:
        off = occupancy_rows[(d, "off")]["occupancy"]
        on = occupancy_rows[(d, "segments")]["occupancy"]
        assert on >= off - 1e-9, (d, off, on)


def test_device_bytes_account_for_padding(occupancy_rows):
    """occupancy == stream/device bytes by construction; the two byte
    counters must stay consistent with the reported ratio."""
    for key, r in occupancy_rows.items():
        assert r["device_mb"] >= r["stream_mb"], key
        assert r["occupancy"] == pytest.approx(
            r["stream_mb"] / r["device_mb"]), key


def test_all_tiny_packed_bit_identical():
    """The acceptance pin behind the occupancy win: the packed scheduler's
    chunking of the all-tiny mix — bounds, lengths, *and* fingerprints —
    is bit-identical to the packing-off scheduler, stream for stream."""
    from repro.core.params import derived_params
    from repro.service import ChunkScheduler

    params = derived_params(8192)
    rng = np.random.default_rng(17)
    streams = [rng.integers(0, 256, int(rng.integers(100, 1000)),
                            dtype=np.uint8) for _ in range(300)]

    def chunk(packing):
        sched = ChunkScheduler(params, slots=8, packing_impl=packing,
                               cross_check_packing=(packing == "segments"))
        for i, s in enumerate(streams):
            sched.submit(s, tag=i)
        return sched.drain()

    off, on = chunk("off"), chunk("segments")
    assert [r.tag for r in on] == [r.tag for r in off] == list(range(300))
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a.bounds, b.bounds)
        np.testing.assert_array_equal(a.lengths, b.lengths)
        np.testing.assert_array_equal(a.fps, b.fps)
