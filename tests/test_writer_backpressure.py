"""service/writer.py backpressure semantics (ISSUE 3 satellite).

The contract under load and under failure:

* a full bounded FIFO *blocks* the producer — it never drops a task and
  never buffers unboundedly;
* a failed store write surfaces as ``AsyncWriteError`` at the barrier,
  *before* any recipe commit or manifest sync runs, with the submitted
  names un-stranded (resubmission works);
* both states are *observable*: the queue-depth gauge and stall-time
  counter move while the FIFO is full, and the writer metrics survive a
  failed flush (the error is consumed at the barrier, the counters are
  not — docs/OBSERVABILITY.md).
"""
import threading
import time

import numpy as np
import pytest

from repro.core.params import SeqCDCParams
from repro.obs import labeled
from repro.service import (
    AsyncWriteError,
    ShardedDedupService,
    ShardWriter,
    WriterPool,
)

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)


def test_full_fifo_blocks_producer_and_drops_nothing():
    w = ShardWriter(max_pending=2)
    gate = threading.Event()
    started = threading.Event()
    ran = []
    w.submit(lambda: (started.set(), gate.wait(30), ran.append(0)))
    assert started.wait(10)  # worker holds task 0; queue is now empty
    w.submit(lambda: ran.append(1))
    w.submit(lambda: ran.append(2))  # queue at max_pending

    submitted = threading.Event()

    def producer():
        w.submit(lambda: ran.append(3))  # must block until the gate opens
        submitted.set()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.3)
    assert not submitted.is_set(), "submit returned on a full queue"

    gate.set()
    assert submitted.wait(10), "producer never unblocked"
    t.join(10)
    w.barrier()
    assert ran == [0, 1, 2, 3]  # FIFO, all four ran, none dropped
    w.close()


def test_backpressure_moves_queue_depth_gauge_and_stall_counter():
    """While the FIFO is full: the depth gauge reads max_pending, and the
    blocked submit's wait lands in the stall-time counter; after the
    barrier the gauge reads 0 and the flushed-bytes counter has every
    payload byte."""
    w = ShardWriter(max_pending=2, shard=0)
    depth = labeled("writer.queue_depth", shard=0)
    stall = labeled("writer.stall_s", shard=0)
    gate = threading.Event()
    started = threading.Event()
    w.submit(lambda: (started.set(), gate.wait(30)), nbytes=10)
    assert started.wait(10)  # worker busy; queue empty
    w.submit(lambda: None, nbytes=10)
    w.submit(lambda: None, nbytes=10)  # queue now at max_pending
    assert w.obs.gauge(depth) == 2
    assert w.obs.counter(stall) == 0  # nothing has blocked yet

    depth_seen = []

    def producer():
        w.submit(lambda: depth_seen.append(w.obs.gauge(depth)), nbytes=10)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.3)  # producer is now blocked inside submit
    gate.set()
    t.join(10)
    w.barrier()
    assert w.obs.counter(stall) >= 0.1, "blocked submit's wait not counted"
    assert w.obs.gauge(depth) == 0, "barrier must reset the depth gauge"
    assert w.obs.counter(labeled("writer.tasks", shard=0)) == 4
    assert w.obs.counter(labeled("writer.flushed_bytes", shard=0)) == 40
    w.close()


def test_unblocked_submits_record_exactly_zero_stall():
    """Regression: submit used to time *every* enqueue, so a busy producer
    accumulated scheduler noise into ``writer.stall_s`` and the counter
    read as perpetual light backpressure.  The fix enqueues with
    ``put_nowait`` and only times the blocking path — a queue that never
    fills must leave the stall counter at exactly 0.0."""
    w = ShardWriter(max_pending=500, shard=5)
    stall = labeled("writer.stall_s", shard=5)
    for i in range(200):  # < max_pending: the FIFO can never fill
        w.submit(lambda: None, nbytes=1)
    w.barrier()
    assert w.obs.counter(stall) == 0.0, \
        "stall counter moved without a single blocked submit"
    assert w.obs.counter(labeled("writer.tasks", shard=5)) == 200
    w.close()


def test_writer_metrics_survive_failed_flush():
    """A failed task is counted (task_errors, tasks) and the error is
    consumed at the barrier — but the registry keeps counting across the
    failure, so retries accumulate into the same counters."""
    w = ShardWriter(max_pending=4, shard=3)
    w.submit(lambda: None, nbytes=100)
    w.submit(lambda: (_ for _ in ()).throw(OSError("disk gone")), nbytes=50)
    with pytest.raises(AsyncWriteError):
        w.barrier()
    assert w.obs.counter(labeled("writer.task_errors", shard=3)) == 1
    assert w.obs.counter(labeled("writer.tasks", shard=3)) == 2
    # the failed task's bytes never flushed
    assert w.obs.counter(labeled("writer.flushed_bytes", shard=3)) == 100
    # the writer keeps working and counting after the consumed error
    w.submit(lambda: None, nbytes=7)
    w.barrier()
    assert w.obs.counter(labeled("writer.flushed_bytes", shard=3)) == 107
    assert w.obs.counter(labeled("writer.tasks", shard=3)) == 3
    hist = w.obs.snapshot()["histograms"][labeled("writer.task_s", shard=3)]
    assert hist["count"] == 3
    w.close()


def test_pool_partial_failure_keeps_other_shards_working():
    pool = WriterPool(2, max_pending=4)
    ran = []
    pool.submit(0, lambda: ran.append("ok"))
    pool.submit(1, lambda: (_ for _ in ()).throw(OSError("disk gone")))
    pool.submit(0, lambda: ran.append("ok2"))
    with pytest.raises(AsyncWriteError):
        pool.barrier()
    assert ran == ["ok", "ok2"]  # the healthy shard drained fully
    pool.barrier()  # error was consumed; the pool is healthy again
    pool.close()


def test_failed_flush_aborts_before_any_commit_or_sync(rng, monkeypatch):
    """AsyncWriteError from a failed block write aborts the flush before
    recipe commit AND before any manifest sync, and the in-flight names are
    released for resubmission."""
    svc = ShardedDedupService(2, params=P, slots=2, min_bucket=1024,
                              async_flush=True, max_pending=4)
    syncs = {"recipes": 0, "stores": 0}
    real_recipe_sync = svc.recipes.sync
    monkeypatch.setattr(
        svc.recipes, "sync",
        lambda: (syncs.__setitem__("recipes", syncs["recipes"] + 1),
                 real_recipe_sync())[-1])
    for st in svc.stores:
        real = st.sync
        monkeypatch.setattr(
            st, "sync",
            lambda real=real: (syncs.__setitem__("stores", syncs["stores"] + 1),
                               real())[-1])

    real_puts = [st.put for st in svc.stores]
    boom = lambda chunk: (_ for _ in ()).throw(OSError("disk gone"))
    for st in svc.stores:
        monkeypatch.setattr(st, "put", boom)

    data = rng.integers(0, 256, 6000, dtype=np.uint8)
    svc.submit("x", data)
    with pytest.raises(AsyncWriteError):
        svc.flush()
    assert len(svc.recipes) == 0, "recipe committed after a failed write"
    assert syncs == {"recipes": 0, "stores": 0}, \
        "manifest/recipe sync ran despite the aborted flush"

    # the name is un-stranded: the same object resubmits and commits
    for st, put in zip(svc.stores, real_puts):
        monkeypatch.setattr(st, "put", put)
    svc.put("x", data)
    assert svc.get("x") == data.tobytes()
    assert syncs["recipes"] > 0 and syncs["stores"] > 0
    svc.close()


def test_sync_mode_inline_error_still_aborts(rng, monkeypatch):
    """max_pending=0 (sync writers): the same abort-before-commit contract
    holds without any worker thread in the loop."""
    svc = ShardedDedupService(2, params=P, slots=2, min_bucket=1024,
                              async_flush=False)
    for st in svc.stores:
        monkeypatch.setattr(
            st, "put", lambda chunk: (_ for _ in ()).throw(OSError("nope")))
    svc.submit("y", rng.integers(0, 256, 4000, dtype=np.uint8))
    with pytest.raises(AsyncWriteError):
        svc.flush()
    assert len(svc.recipes) == 0
    svc.close()
